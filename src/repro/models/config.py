"""Model configuration covering all assigned architecture families.

One frozen dataclass parameterizes: dense / MoE / SSM (Mamba-2 SSD) / hybrid
(parallel attn+SSM heads) / encoder-decoder / VLM (periodic cross-attention)
transformers.  Every assigned arch in ``repro.configs`` instantiates this.
"""
from __future__ import annotations

import dataclasses
import math

__all__ = ["ModelConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int                     # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int                        # dense-MLP hidden (0 if no MLP, e.g. mamba2)
    vocab_size: int
    head_dim: int = 0                # 0 → d_model // n_heads

    # -- MoE ------------------------------------------------------------------
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim
    moe_capacity_factor: float = 1.25

    # -- attention --------------------------------------------------------------
    window: int = 0                  # sliding-window size (0 = full attention)
    rope_theta: float = 10000.0
    qk_norm: bool = False

    # -- SSM (Mamba-2 / SSD) -----------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_groups: int = 1

    # -- structure ----------------------------------------------------------------
    enc_layers: int = 0              # >0 → encoder-decoder (n_layers = decoder)
    cross_attn_period: int = 0       # vlm: one cross-attn layer every k layers
    num_modal_tokens: int = 0        # stubbed frontend sequence length
    norm: str = "rmsnorm"            # rmsnorm | layernorm | layernorm_np
    mlp: str = "swiglu"              # swiglu | gelu_mlp
    use_bias: bool = False
    tie_embeddings: bool = False

    # -- numerics ---------------------------------------------------------------
    dtype: str = "bfloat16"          # activation/param compute dtype
    source: str = ""                 # provenance citation

    # ------------------------------------------------------------------ derived
    @property
    def hd(self) -> int:
        if self.n_heads == 0:
            return 0
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding shards over
        any mesh axis ≤256 (MaxText-style padding; padded logits are masked
        in the loss).  Exact vocab stays in ``vocab_size``."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def is_moe(self) -> bool:
        return self.moe_num_experts > 0

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model if self.ssm_state else 0

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def attn_free(self) -> bool:
        return self.n_heads == 0

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode with O(1)-in-seq cache (SSM state or window)?"""
        return self.family in ("ssm", "hybrid") or self.window > 0

    def cache_len(self, seq_len: int) -> int:
        """KV-cache length needed to decode with ``seq_len`` tokens of context."""
        return min(seq_len, self.window) if self.window else seq_len

    # ------------------------------------------------------- parameter counting
    def param_count(self) -> int:
        """Exact parameter count of the model as constructed in models/model.py."""
        from . import model  # local import to avoid cycle

        import jax

        specs = model.param_specs(self)
        leaves = jax.tree.leaves(
            specs, is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "logical"))
        return sum(int(math.prod(p.shape)) for p in leaves)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        total = self.param_count()
        if not self.is_moe:
            return total
        from . import model

        import jax

        specs = model.param_specs(self)
        expert, shared = 0, 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: hasattr(x, "logical")
        )[0]:
            n = int(math.prod(leaf.shape))
            if "experts" in leaf.logical:
                expert += n
            else:
                shared += n
        active_expert = expert * self.moe_top_k // self.moe_num_experts
        return shared + active_expert

    # ------------------------------------------------------------------ reduced
    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests (shapes only)."""
        return dataclasses.replace(
            self,
            n_layers=2,
            d_model=64,
            n_heads=max(2, min(4, self.n_heads)) if self.n_heads else 0,
            n_kv_heads=min(2, self.n_kv_heads) if self.n_kv_heads else 0,
            head_dim=16 if self.n_heads else 0,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            moe_num_experts=4 if self.is_moe else 0,
            moe_top_k=min(2, self.moe_top_k) if self.is_moe else 0,
            moe_d_ff=64 if self.is_moe else 0,
            window=16 if self.window else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            enc_layers=2 if self.enc_layers else 0,
            cross_attn_period=2 if self.cross_attn_period else 0,
            num_modal_tokens=8 if self.num_modal_tokens else 0,
            dtype="float32",
        )

    def validate(self) -> "ModelConfig":
        assert self.family in ("dense", "moe", "ssm", "hybrid", "encdec", "vlm"), self.family
        if self.n_heads:
            assert self.n_heads % max(self.n_kv_heads, 1) == 0, "GQA requires heads % kv_heads == 0"
        if self.is_moe:
            assert 0 < self.moe_top_k <= self.moe_num_experts
        if self.family == "ssm":
            assert self.ssm_state > 0 and self.ssm_d_inner % self.ssm_head_dim == 0
        if self.family == "hybrid":
            assert self.ssm_state > 0 and self.n_heads > 0
        if self.family == "encdec":
            assert self.enc_layers > 0
        if self.family == "vlm":
            assert self.cross_attn_period > 0 and self.n_layers % self.cross_attn_period == 0
        return self
