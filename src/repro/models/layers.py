"""Shared layers: param specs, norms, MLPs, rotary embeddings.

Parameters are declared via :class:`P` leaf specs carrying *logical axis*
names (t5x/MaxText style).  A single spec tree is the source of truth for
initialization, sharding (``repro.parallel.sharding`` maps logical → mesh
axes) and the dry-run's ShapeDtypeStructs.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig

__all__ = ["P", "init_leaf", "norm_params", "apply_norm", "mlp_params", "apply_mlp", "rope", "dtype_of"]


class P:
    """Parameter/state leaf spec: shape + logical axes + init scheme.

    ``dtype`` (optional) pins the leaf's dtype (e.g. fp32 SSM decay params,
    fp32 SSD state); None defers to the caller's default (model dtype).
    """

    __slots__ = ("shape", "logical", "init", "scale", "dtype")

    def __init__(self, shape: Tuple[int, ...], logical: Tuple[Optional[str], ...],
                 init: str = "normal", scale: float = 1.0, dtype: Optional[str] = None):
        assert len(shape) == len(logical), (shape, logical)
        self.shape = tuple(int(s) for s in shape)
        self.logical = tuple(logical)
        self.init = init
        self.scale = scale
        self.dtype = dtype

    def with_dtype(self, default) -> Any:
        return jnp.dtype(self.dtype) if self.dtype else jnp.dtype(default)

    def __repr__(self) -> str:
        return f"P{self.shape}:{self.logical}:{self.init}"


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def init_leaf(key: jax.Array, p: P, dtype) -> jax.Array:
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "normal":
        # fan-in scaled truncated-normal-ish init
        fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
        std = p.scale / math.sqrt(max(fan_in, 1))
        return (std * jax.random.normal(key, p.shape)).astype(dtype)
    if p.init == "embed":
        return (0.02 * jax.random.normal(key, p.shape)).astype(dtype)
    if p.init == "ssm_a":  # A_log init: log of uniform [1, 16]
        u = jax.random.uniform(key, p.shape, minval=1.0, maxval=16.0)
        return jnp.log(u).astype(jnp.float32)  # keep SSM decay params fp32
    if p.init == "ssm_dt":  # dt bias: softplus-inv of uniform dt in [1e-3, 1e-1]
        u = jax.random.uniform(key, p.shape, minval=math.log(1e-3), maxval=math.log(1e-1))
        dt = jnp.exp(u)
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32)
    raise ValueError(p.init)


# ---------------------------------------------------------------------- norms
def norm_params(cfg: ModelConfig, layers_axis: bool = True) -> Dict[str, P]:
    """Norm params; 'layernorm_np' (OLMo non-parametric LN) has none."""
    if cfg.norm == "layernorm_np":
        return {}
    lead: Tuple[int, ...] = ()
    llog: Tuple[Optional[str], ...] = ()
    out = {"scale": P((cfg.d_model,), ("d_model",), "ones")}
    if cfg.norm == "layernorm" and cfg.use_bias:
        out["bias"] = P((cfg.d_model,), ("d_model",), "zeros")
    return out


def apply_norm(params: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig, eps: float = 1e-5) -> jax.Array:
    from ..parallel.sharding import constrain  # local: avoid import cycle

    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        y = y * params["scale"].astype(jnp.float32)
    else:  # layernorm / layernorm_np
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        if cfg.norm == "layernorm":
            y = y * params["scale"].astype(jnp.float32)
            if "bias" in params:
                y = y + params["bias"].astype(jnp.float32)
    y = y.astype(x.dtype)
    # pin the (bf16) norm output to the residual layout: without this GSPMD
    # sometimes hoists the SP all-gather above the fp32→bf16 convert and the
    # fp32 normed activations get gathered AND saved for backward (2× bytes)
    if y.ndim == 3:
        y = constrain(y, ("batch", "seq", None))
    return y


# ----------------------------------------------------------------------- MLPs
def mlp_params(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict[str, P]:
    f = d_ff or cfg.d_ff
    d = cfg.d_model
    if cfg.mlp == "swiglu":
        out = {
            "wi_gate": P((d, f), ("d_model", "d_ff")),
            "wi_up": P((d, f), ("d_model", "d_ff")),
            "wo": P((f, d), ("d_ff", "d_model"), scale=1.0 / math.sqrt(2 * cfg.n_layers or 2)),
        }
    else:  # gelu_mlp
        out = {
            "wi": P((d, f), ("d_model", "d_ff")),
            "wo": P((f, d), ("d_ff", "d_model"), scale=1.0 / math.sqrt(2 * cfg.n_layers or 2)),
        }
        if cfg.use_bias:
            out["bi"] = P((f,), ("d_ff",), "zeros")
            out["bo"] = P((d,), ("d_model",), "zeros")
    return out


def apply_mlp(params: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.mlp == "swiglu":
        g = jnp.einsum("...d,df->...f", x, params["wi_gate"])
        u = jnp.einsum("...d,df->...f", x, params["wi_up"])
        h = jax.nn.silu(g) * u
        return jnp.einsum("...f,fd->...d", h, params["wo"])
    h = jnp.einsum("...d,df->...f", x, params["wi"])
    if "bi" in params:
        h = h + params["bi"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("...f,fd->...d", h, params["wo"])
    if "bo" in params:
        y = y + params["bo"]
    return y


# -------------------------------------------------------------------- rotary
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply rotary embedding. x: (..., seq, heads, hd); positions: (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(ang)[..., :, None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)
